//! Property-based tests over the coordinator: random workloads and DLB
//! settings must preserve the runtime's global invariants.
//!
//! Built on `ductr::util::propcheck` (the in-repo proptest substitute) —
//! every case is reproducible from the reported seed.

use std::collections::BinaryHeap;
use std::sync::Arc;

use ductr::apps::{bag, rand_dag};
use ductr::config::{Config, PolicyKind, Strategy, TopologyKind, WindowMode};
use ductr::core::graph::TaskGraph;
use ductr::core::ids::ProcessId;
use ductr::dlb::policy::SosParams;
use ductr::net::graph::{self, GraphTopo};
use ductr::net::topology::Topology;
use ductr::sim::calendar::CalendarQueue;
use ductr::sim::engine::SimEngine;
use ductr::util::propcheck::{forall, Gen};

/// Random (workload, config) scenario.
#[derive(Debug, Clone)]
struct Scenario {
    processes: usize,
    dlb: bool,
    strategy: Strategy,
    wt: usize,
    delta: f64,
    seed: u64,
    kind: u8, // 0 = bag, 1 = layered dag
    tasks: usize,
}

fn gen_scenario(g: &mut Gen) -> Scenario {
    Scenario {
        processes: g.usize_in(2..9).max(2),
        dlb: g.bool(),
        strategy: *[Strategy::Basic, Strategy::Equalizing, Strategy::Smart]
            .iter()
            .nth(g.usize_in(0..3).min(2))
            .expect("index"),
        wt: g.usize_in(1..8).max(1),
        delta: g.f64_in(0.0002..0.01),
        seed: g.u64_in(1..1_000_000),
        kind: if g.bool() { 0 } else { 1 },
        tasks: g.usize_in(4..120).max(4),
    }
}

fn build_graph(s: &Scenario) -> Arc<TaskGraph> {
    match s.kind {
        0 => bag::build(
            s.processes,
            bag::BagParams {
                tasks: s.tasks,
                mean_flops: 5_000_000,
                skew: 2.5,
                size_spread: 0.6,
                block: 64,
            },
            s.seed,
        ),
        _ => rand_dag::build(
            s.processes,
            rand_dag::DagParams {
                layers: (s.tasks / 8).clamp(2, 12),
                width: 8,
                max_deps: 3,
                mean_flops: 5_000_000,
                block: 64,
            },
            s.seed,
        ),
    }
}

fn config_of(s: &Scenario) -> Config {
    let mut c = Config::default();
    c.processes = s.processes;
    c.grid = None;
    c.dlb_enabled = s.dlb;
    c.strategy = s.strategy;
    c.wt = s.wt;
    c.delta = s.delta;
    c.seed = s.seed;
    c.validate().expect("generated config valid");
    c
}

#[test]
fn prop_every_run_terminates_and_drains() {
    forall(60, 0xD0C5, gen_scenario, |s| -> Result<(), String> {
        let g = build_graph(s);
        let n_tasks = g.num_tasks();
        let cfg = config_of(s);
        let mut eng = SimEngine::from_config(&cfg, g);
        eng.max_time = 3600.0;
        let r = eng.run().map_err(|e| format!("{s:?}: {e}"))?;
        if n_tasks > 0 && r.makespan <= 0.0 {
            return Err(format!("{s:?}: zero makespan with {n_tasks} tasks"));
        }
        for (i, tr) in r.traces.per_process.iter().enumerate() {
            if let Some(&(_, w)) = tr.samples().last() {
                if w != 0 {
                    return Err(format!("{s:?}: p{i} queue not drained (w={w})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_export_import_balance() {
    forall(40, 0xBA1A, gen_scenario, |s| -> Result<(), String> {
        let g = build_graph(s);
        let cfg = config_of(s);
        let r = SimEngine::from_config(&cfg, g).run().map_err(|e| format!("{e}"))?;
        if r.counters.tasks_exported != r.counters.tasks_received {
            return Err(format!(
                "{s:?}: exported {} != received {}",
                r.counters.tasks_exported, r.counters.tasks_received
            ));
        }
        if !s.dlb && r.counters.tasks_exported != 0 {
            return Err(format!("{s:?}: migrations with DLB off"));
        }
        Ok(())
    });
}

#[test]
fn prop_deterministic_replay() {
    forall(20, 0xDE7E, gen_scenario, |s| -> Result<(), String> {
        let cfg = config_of(s);
        let a = SimEngine::from_config(&cfg, build_graph(s))
            .run()
            .map_err(|e| format!("{e}"))?;
        let b = SimEngine::from_config(&cfg, build_graph(s))
            .run()
            .map_err(|e| format!("{e}"))?;
        if a.makespan != b.makespan || a.events_processed != b.events_processed {
            return Err(format!(
                "{s:?}: nondeterministic ({} vs {} events)",
                a.events_processed, b.events_processed
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_dlb_never_catastrophic() {
    // DLB may add overhead but must never blow the makespan up by 2× on
    // these workloads (it is allowed to be mildly worse — the paper's Fig 5
    // left shows a no-benefit run).
    forall(25, 0xCA7A, gen_scenario, |s| -> Result<(), String> {
        let mut on = s.clone();
        on.dlb = true;
        let mut off = s.clone();
        off.dlb = false;
        let r_on = SimEngine::from_config(&config_of(&on), build_graph(&on))
            .run()
            .map_err(|e| format!("{e}"))?;
        let r_off = SimEngine::from_config(&config_of(&off), build_graph(&off))
            .run()
            .map_err(|e| format!("{e}"))?;
        if r_on.makespan > r_off.makespan * 2.0 + 0.05 {
            return Err(format!(
                "{s:?}: DLB catastrophic: on={} off={}",
                r_on.makespan, r_off.makespan
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// topology-distance contract (PR 4): `hops` must be a total metric-like
// function over *arbitrary* (shape, P) combinations — including shapes
// whose dimensions do not cover P, the aliasing bug this PR fixed.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct TopoCase {
    topo: Topology,
    p: usize,
}

/// A random connected simple graph: a uniform spanning tree (each node
/// attaches to an earlier one) plus extra random chords.  `from_edges`
/// dedupes the chords and guarantees connectivity, so `expect` is safe.
fn gen_graph(g: &mut Gen) -> Arc<GraphTopo> {
    let n = g.usize_in(2..13).max(2);
    let mut edges = Vec::new();
    for i in 1..n {
        edges.push((g.usize_in(0..i), i));
    }
    for _ in 0..g.usize_in(0..n) {
        let a = g.usize_in(0..n);
        let b = g.usize_in(0..n);
        if a != b {
            edges.push((a, b));
        }
    }
    Arc::new(GraphTopo::from_edges(n, &edges, "prop-rand").expect("spanning tree is connected"))
}

fn gen_shape(g: &mut Gen) -> Topology {
    match g.usize_in(0..5) {
        0 => Topology::Flat,
        1 => Topology::Ring { len: g.usize_in(1..13) },
        2 => Topology::Torus { rows: g.usize_in(1..6), cols: g.usize_in(1..6) },
        3 => Topology::Cluster {
            nodes: g.usize_in(1..6),
            per_node: g.usize_in(1..6),
            inter_hops: g.usize_in(1..8) as u32,
        },
        _ => Topology::Graph(gen_graph(g)),
    }
}

/// Shape and process count drawn independently: P may exceed, match, or
/// undershoot the shape's slot count.
fn gen_topo(g: &mut Gen) -> TopoCase {
    TopoCase { topo: gen_shape(g), p: g.usize_in(2..24).max(2) }
}

#[test]
fn prop_hops_zero_diagonal_positive_symmetric() {
    forall(150, 0x4095, gen_topo, |c| -> Result<(), String> {
        for i in 0..c.p {
            for j in 0..c.p {
                let (a, b) = (ProcessId(i as u32), ProcessId(j as u32));
                let h = c.topo.hops(a, b);
                let back = c.topo.hops(b, a);
                if h != back {
                    return Err(format!("{c:?}: hops({i},{j})={h} but hops({j},{i})={back}"));
                }
                if i == j && h != 0 {
                    return Err(format!("{c:?}: hops({i},{i}) = {h}, want 0"));
                }
                if i != j && h == 0 {
                    return Err(format!(
                        "{c:?}: hops({i},{j}) = 0 for distinct processes (contract: ≥ 1)"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Covering shapes (P = slot count, the only configurations `validate`
/// accepts): every rank's neighbor set is non-empty, self-free, symmetric,
/// and the neighbor graph is connected — diffusion's liveness conditions.
fn gen_covering(g: &mut Gen) -> TopoCase {
    match g.usize_in(0..8).min(7) {
        0 => TopoCase { topo: Topology::Flat, p: g.usize_in(2..24).max(2) },
        1 => {
            let len = g.usize_in(2..16).max(2);
            TopoCase { topo: Topology::Ring { len }, p: len }
        }
        2 => {
            let rows = g.usize_in(2..6).max(2);
            let cols = g.usize_in(1..6);
            TopoCase { topo: Topology::Torus { rows, cols }, p: rows * cols }
        }
        3 => {
            let nodes = g.usize_in(2..6).max(2);
            let per_node = g.usize_in(1..6);
            TopoCase {
                topo: Topology::Cluster { nodes, per_node, inter_hops: g.usize_in(1..8) as u32 },
                p: nodes * per_node,
            }
        }
        4 => {
            let gr = gen_graph(g);
            let p = gr.n();
            TopoCase { topo: Topology::Graph(gr), p }
        }
        5 => {
            let (a, rp) = (g.usize_in(2..4).max(2), g.usize_in(1..3).max(1));
            let gr = graph::dragonfly(a, rp, 1).expect("valid dragonfly params");
            let p = gr.n();
            TopoCase { topo: Topology::Graph(Arc::new(gr)), p }
        }
        6 => {
            let k = 2 * g.usize_in(1..3).max(1); // 2 or 4
            let gr = graph::fat_tree(k).expect("valid fat-tree k");
            let p = gr.n();
            TopoCase { topo: Topology::Graph(Arc::new(gr)), p }
        }
        _ => {
            let n = 2 * g.usize_in(2..7).max(2); // even, 4..12
            let gr = graph::random_regular(n, 3, g.u64_in(1..1_000_000))
                .expect("3-regular pairing exists for even n ≥ 4");
            let p = gr.n();
            TopoCase { topo: Topology::Graph(Arc::new(gr)), p }
        }
    }
}

#[test]
fn prop_neighbors_symmetric_connected_nonempty() {
    forall(150, 0xBEEF, gen_covering, |c| -> Result<(), String> {
        assert!(c.topo.covers(c.p), "generator bug: {c:?}");
        for i in 0..c.p {
            let me = ProcessId(i as u32);
            let n = c.topo.neighbors(me, c.p);
            if n.is_empty() {
                return Err(format!("{c:?}: rank {i} stranded (empty neighbor set)"));
            }
            if n.contains(&me) {
                return Err(format!("{c:?}: rank {i} neighbors itself"));
            }
            for q in &n {
                if !c.topo.neighbors(*q, c.p).contains(&me) {
                    return Err(format!("{c:?}: {i} lists {q} but not vice versa"));
                }
            }
        }
        // connectivity: BFS from rank 0 must reach everyone
        let mut seen = vec![false; c.p];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(i) = stack.pop() {
            for q in c.topo.neighbors(ProcessId(i as u32), c.p) {
                if !seen[q.idx()] {
                    seen[q.idx()] = true;
                    stack.push(q.idx());
                }
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(format!("{c:?}: neighbor graph disconnected"));
        }
        Ok(())
    });
}

/// The distance-ranked victim table agrees with `hops` and loses nobody.
#[test]
fn prop_distance_ranking_is_complete_and_sorted() {
    forall(100, 0x8A1E, gen_covering, |c| -> Result<(), String> {
        for i in 0..c.p {
            let me = ProcessId(i as u32);
            let ranked = c.topo.neighbors_by_distance(me, c.p);
            if ranked.len() != c.p - 1 {
                return Err(format!("{c:?}: rank {i} table has {} entries", ranked.len()));
            }
            for &(q, h) in &ranked {
                if h != c.topo.hops(me, q) {
                    return Err(format!("{c:?}: table distance {h} ≠ hops for {q}"));
                }
                if h == 0 {
                    return Err(format!("{c:?}: zero-distance entry {q}"));
                }
            }
            for w in ranked.windows(2) {
                if (w[0].1, w[0].0.idx()) >= (w[1].1, w[1].0.idx()) {
                    return Err(format!("{c:?}: table not sorted at {w:?}"));
                }
            }
        }
        Ok(())
    });
}

/// On covering shapes `hops` is a genuine metric: the triangle inequality
/// must hold through every intermediate rank.  (Out-of-shape ranks are
/// excluded — their distance is pinned to 1 by contract, which is not a
/// metric completion.)
#[test]
fn prop_hops_triangle_inequality_on_covering_shapes() {
    forall(60, 0x7419, gen_covering, |c| -> Result<(), String> {
        let h = |a: usize, b: usize| c.topo.hops(ProcessId(a as u32), ProcessId(b as u32));
        for a in 0..c.p {
            for b in 0..c.p {
                let direct = h(a, b);
                for m in 0..c.p {
                    if direct > h(a, m) + h(m, b) {
                        return Err(format!(
                            "{c:?}: hops({a},{b})={direct} > hops({a},{m}) + hops({m},{b}) \
                             = {} + {}",
                            h(a, m),
                            h(m, b)
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The BFS distance table behind `Topology::Graph` is complete (no
/// unreachable pair survives construction), symmetric, zero exactly on the
/// diagonal, 1 exactly on CSR adjacency, triangle-consistent, and its
/// maximum is the advertised diameter.
#[test]
fn prop_graph_distance_table_complete_and_metric() {
    forall(60, 0x94AF, gen_graph, |g| -> Result<(), String> {
        let n = g.n();
        let mut max_d = 0u32;
        for i in 0..n {
            let row = g.dist_row(i);
            if row.len() != n {
                return Err(format!("{g:?}: row {i} has {} entries, want {n}", row.len()));
            }
            for j in 0..n {
                let d = row[j];
                if d == u16::MAX {
                    return Err(format!("{g:?}: table hole at ({i},{j})"));
                }
                if (i == j) != (d == 0) {
                    return Err(format!("{g:?}: dist({i},{j}) = {d}"));
                }
                if d != g.dist_row(j)[i] {
                    return Err(format!("{g:?}: table asymmetric at ({i},{j})"));
                }
                let adjacent = g.neighbors_of(i).contains(&(j as u32));
                if adjacent != (d == 1) {
                    return Err(format!(
                        "{g:?}: adjacency and distance disagree at ({i},{j}): adj={adjacent} d={d}"
                    ));
                }
                max_d = max_d.max(d as u32);
            }
        }
        if max_d != g.diameter() {
            return Err(format!("{g:?}: max table entry {max_d} ≠ diameter {}", g.diameter()));
        }
        for a in 0..n {
            for b in 0..n {
                for m in 0..n {
                    if g.dist_row(a)[b] > g.dist_row(a)[m] + g.dist_row(m)[b] {
                        return Err(format!("{g:?}: BFS triangle violated at ({a},{m},{b})"));
                    }
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// calendar-queue scheduler (PR 5): the DES event queue must pop in exactly
// the `(time, seq)` total order the old `BinaryHeap` produced — the oracle
// below *is* that heap's ordering, kept alive as test-only code.
// ---------------------------------------------------------------------

/// The pre-calendar event ordering, verbatim: a max-heap reversed on
/// `(t, seq)` so `pop` yields earliest-first, ties in insertion order.
#[derive(Debug, PartialEq)]
struct OracleEntry {
    t: f64,
    seq: u64,
}

impl Eq for OracleEntry {}
impl PartialOrd for OracleEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OracleEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other
            .t
            .partial_cmp(&self.t)
            .expect("no NaN times")
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A random scheduler workload: each element is one operation.  Values of
/// `op` select pushes of several flavors (plain near-future, same-timestamp
/// burst, far-future outlier, tick-style re-arm pair) or an interleaved
/// pop; `a` parameterizes the timestamps.
fn gen_stream(g: &mut Gen) -> Vec<(usize, usize)> {
    let n = g.usize_in(4..400).max(4);
    (0..n).map(|_| (g.rng().range_usize(0, 12), g.rng().range_usize(0, 5000))).collect()
}

#[test]
fn prop_calendar_pop_order_matches_heap_oracle() {
    forall(120, 0xCA1E, gen_stream, |ops| -> Result<(), String> {
        let mut cal: CalendarQueue<()> = CalendarQueue::new();
        let mut oracle: BinaryHeap<OracleEntry> = BinaryHeap::new();
        let mut seq = 0u64;
        let mut now = 0.0f64;
        let push = |cal: &mut CalendarQueue<()>,
                        oracle: &mut BinaryHeap<OracleEntry>,
                        seq: &mut u64,
                        t: f64| {
            *seq += 1;
            cal.push(t, *seq, ());
            oracle.push(OracleEntry { t, seq: *seq });
        };
        for &(op, a) in ops {
            match op {
                // plain near-future push (µs scale, the control-plane regime)
                0..=4 => push(&mut cal, &mut oracle, &mut seq, now + a as f64 * 1e-6),
                // same-timestamp burst: ties must resolve by seq
                5 | 6 => {
                    let t = now + a as f64 * 1e-6;
                    for _ in 0..3 {
                        push(&mut cal, &mut oracle, &mut seq, t);
                    }
                }
                // far-future outlier (seconds out: the overflow list)
                7 => push(&mut cal, &mut oracle, &mut seq, now + 1_000.0 + a as f64),
                // tick re-arm: a later deadline pushed first, then its
                // earlier replacement — both must still pop in (t, seq)
                // order (the engine drops the stale one by generation)
                8 => {
                    let t_old = now + (2 * a + 2) as f64 * 1e-6;
                    let t_new = now + (a + 1) as f64 * 1e-6;
                    push(&mut cal, &mut oracle, &mut seq, t_old);
                    push(&mut cal, &mut oracle, &mut seq, t_new);
                }
                // interleaved pop
                _ => {
                    let c = cal.pop();
                    let o = oracle.pop();
                    match (&c, &o) {
                        (None, None) => {}
                        (Some(ce), Some(oe)) => {
                            if ce.t != oe.t || ce.seq != oe.seq {
                                return Err(format!(
                                    "pop mismatch: calendar ({}, {}) vs oracle ({}, {})",
                                    ce.t, ce.seq, oe.t, oe.seq
                                ));
                            }
                            now = ce.t;
                        }
                        _ => return Err(format!("length mismatch: {c:?} vs {o:?}")),
                    }
                }
            }
        }
        // full drain must agree too
        loop {
            match (cal.pop(), oracle.pop()) {
                (None, None) => break,
                (Some(ce), Some(oe)) if ce.t == oe.t && ce.seq == oe.seq => {}
                (c, o) => return Err(format!("drain mismatch: {c:?} vs {o:?}")),
            }
        }
        Ok(())
    });
}

/// Coalescing is pure transport batching: on workloads where no step ever
/// emits two messages to one destination (random DAGs with DLB off — task
/// completions send one grouped `TaskDone` per remote consumer), switching
/// it on must not move a single bit of the run.
#[test]
fn prop_coalesce_identity_without_multi_send_steps() {
    forall(15, 0xC0A1, gen_scenario, |s| -> Result<(), String> {
        let mut s = s.clone();
        s.kind = 1; // layered DAG: layer-0 tasks have no v0 fan-out
        s.dlb = false;
        let mut cfg_off = config_of(&s);
        cfg_off.coalesce = false;
        let mut cfg_on = cfg_off.clone();
        cfg_on.coalesce = true;
        let off = SimEngine::from_config(&cfg_off, build_graph(&s))
            .run()
            .map_err(|e| format!("{e}"))?;
        let on = SimEngine::from_config(&cfg_on, build_graph(&s))
            .run()
            .map_err(|e| format!("{e}"))?;
        if on.counters.messages_coalesced != 0 {
            return Err(format!(
                "{s:?}: a ≤1-message-per-destination workload coalesced {} messages",
                on.counters.messages_coalesced
            ));
        }
        if on.makespan.to_bits() != off.makespan.to_bits()
            || on.events_processed != off.events_processed
        {
            return Err(format!(
                "{s:?}: coalesce on/off diverged (makespan {} vs {}, events {} vs {})",
                on.makespan, off.makespan, on.events_processed, off.events_processed
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// span recorder (PR 6): arming the tracer must be invisible to the run,
// and every recorded interval must be well-formed — ends after it starts,
// stamps monotone within a process stream, queue waits non-negative, and
// exec spans non-overlapping on the default single-core processes.
// ---------------------------------------------------------------------

#[test]
fn prop_trace_spans_well_formed_and_run_unperturbed() {
    use ductr::metrics::TraceEvent;
    const EPS: f64 = 1e-9;
    forall(25, 0x7ACE, gen_scenario, |s| -> Result<(), String> {
        let plain = SimEngine::from_config(&config_of(s), build_graph(s))
            .run()
            .map_err(|e| format!("{e}"))?;
        let mut cfg = config_of(s);
        cfg.trace_enabled = true;
        let traced = SimEngine::from_config(&cfg, build_graph(s))
            .run()
            .map_err(|e| format!("{e}"))?;
        if traced.makespan.to_bits() != plain.makespan.to_bits()
            || traced.events_processed != plain.events_processed
        {
            return Err(format!(
                "{s:?}: tracing perturbed the run (makespan {} vs {}, events {} vs {})",
                traced.makespan, plain.makespan, traced.events_processed, plain.events_processed
            ));
        }
        if traced.trace.total_events() == 0 {
            return Err(format!("{s:?}: recorder armed but nothing recorded"));
        }
        for (i, evs) in traced.trace.per_process.iter().enumerate() {
            let mut prev_t = f64::NEG_INFINITY;
            let mut execs: Vec<(f64, f64)> = Vec::new();
            for e in evs {
                let t = e.time();
                if t < prev_t - EPS {
                    return Err(format!("{s:?}: p{i} event stamps went backwards at {e:?}"));
                }
                prev_t = prev_t.max(t);
                match *e {
                    TraceEvent::RoundEnd { started, requested, t, .. } => {
                        if started > requested + EPS || requested > t + EPS {
                            return Err(format!("{s:?}: p{i} malformed round span {e:?}"));
                        }
                    }
                    TraceEvent::ExecStart { queue_wait, .. } => {
                        if queue_wait < 0.0 {
                            return Err(format!("{s:?}: p{i} negative queue wait {e:?}"));
                        }
                    }
                    TraceEvent::ExecEnd { started, t, .. } => {
                        if started > t + EPS {
                            return Err(format!("{s:?}: p{i} exec ends before start {e:?}"));
                        }
                        execs.push((started, t));
                    }
                    TraceEvent::MsgFlight { sent, t, .. } => {
                        if sent > t + EPS {
                            return Err(format!("{s:?}: p{i} flight arrives before send {e:?}"));
                        }
                    }
                    _ => {}
                }
            }
            execs.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
            for w in execs.windows(2) {
                if w[1].0 < w[0].1 - EPS {
                    return Err(format!(
                        "{s:?}: p{i} overlapping exec spans {:?} and {:?} on one core",
                        w[0], w[1]
                    ));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// sharded parallel engine (PR 7): under any policy × topology × process
// count × shard count, the conservatively-windowed engine must be
// *bit-identical* to the single-threaded oracle — makespan bits, event
// count, and every DLB counter, aggregate and per-rank.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct ShardScenario {
    base: Scenario,
    policy: PolicyKind,
    topology: TopologyKind,
    shards: usize,
}

fn gen_shard_scenario(g: &mut Gen) -> ShardScenario {
    let mut base = gen_scenario(g);
    // keep P small enough that 25 dual runs stay fast, large enough that
    // every shard count in the table can actually split the ranks
    base.processes = g.usize_in(2..17).max(2);
    let topology = [
        TopologyKind::Flat,
        TopologyKind::Ring,
        TopologyKind::Torus,
        TopologyKind::Cluster,
        TopologyKind::RandReg { d: 3 },
    ][g.usize_in(0..5).min(4)];
    if matches!(topology, TopologyKind::RandReg { .. }) {
        // 3-regular graphs need an even rank count of at least 4
        base.processes = (base.processes.max(4) + 1) & !1;
    }
    ShardScenario {
        base,
        policy: PolicyKind::ALL[g.usize_in(0..PolicyKind::ALL.len()).min(PolicyKind::ALL.len() - 1)],
        topology,
        shards: [1, 2, 3, 8][g.usize_in(0..4).min(3)],
    }
}

#[test]
fn prop_sharded_engine_bit_identical_to_single_thread() {
    forall(25, 0x5A4D, gen_shard_scenario, |s| -> Result<(), String> {
        let mut cfg = config_of(&s.base);
        cfg.policy = s.policy;
        cfg.topology = s.topology;
        cfg.validate().map_err(|e| format!("{s:?}: {e}"))?;
        let g = build_graph(&s.base);
        let single = SimEngine::from_config(&cfg, Arc::clone(&g))
            .run()
            .map_err(|e| format!("{s:?}: single: {e}"))?;
        // Both barrier protocols — the distance-aware per-shard horizons
        // with sparse barriers (Matrix) and the legacy global-minimum
        // lookahead (Scalar) — must reproduce the oracle bit-for-bit on
        // every policy × topology × shard-count draw.
        let shards = s.shards.min(s.base.processes);
        // Block rounding can populate fewer shards than requested (e.g.
        // 5 ranks over 4 shards → blocks of 2 → 3 shards); the command
        // accounting below needs the count the engine actually built.
        let part = cfg.build_topology().shard_partition(s.base.processes, shards);
        let built = part.iter().copied().max().map_or(1u64, |m| m as u64 + 1);
        let mut stats = Vec::new();
        for mode in [WindowMode::Matrix, WindowMode::Scalar] {
            let mut pcfg = cfg.clone();
            pcfg.sim_threads = shards;
            pcfg.sim_window = mode;
            pcfg.validate().map_err(|e| format!("{s:?}: {e}"))?;
            let par = ductr::sim::run_config(&pcfg, Arc::clone(&g))
                .map_err(|e| format!("{s:?} [{mode}]: sharded: {e}"))?;
            if par.makespan.to_bits() != single.makespan.to_bits() {
                return Err(format!(
                    "{s:?} [{mode}]: makespan diverged ({} vs {})",
                    par.makespan, single.makespan
                ));
            }
            if par.events_processed != single.events_processed {
                return Err(format!(
                    "{s:?} [{mode}]: event count diverged ({} vs {})",
                    par.events_processed, single.events_processed
                ));
            }
            if par.counters != single.counters {
                return Err(format!(
                    "{s:?} [{mode}]: aggregate counters diverged\n  sharded {:?}\n  single  {:?}",
                    par.counters, single.counters
                ));
            }
            if par.per_process_counters != single.per_process_counters {
                return Err(format!("{s:?} [{mode}]: per-process counters diverged"));
            }
            stats.push(par.window);
        }
        let (matrix, scalar) = (stats[0], stats[1]);
        if shards > 1 {
            // Window-stat consistency: every window classifies each shard
            // as commanded or skipped; the scalar protocol never skips;
            // per-pair horizons dominate the global one, so the matrix
            // protocol never needs more windows.
            for (mode, w) in [("matrix", matrix), ("scalar", scalar)] {
                if w.windows == 0 {
                    return Err(format!("{s:?} [{mode}]: sharded run recorded no windows"));
                }
                if w.cmds_sent + w.cmds_skipped != w.windows * built {
                    return Err(format!(
                        "{s:?} [{mode}]: {} sent + {} skipped != {} windows x {built} shards",
                        w.cmds_sent, w.cmds_skipped, w.windows
                    ));
                }
            }
            if scalar.cmds_skipped != 0 {
                return Err(format!("{s:?}: scalar protocol skipped {} cmds", scalar.cmds_skipped));
            }
            if matrix.windows > scalar.windows {
                return Err(format!(
                    "{s:?}: matrix took {} windows, scalar {}",
                    matrix.windows, scalar.windows
                ));
            }
        } else if matrix != Default::default() || scalar != Default::default() {
            return Err(format!("{s:?}: single-shard run recorded window stats"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// second-order diffusion (PR 9): on the idealized continuous-load
// iteration — the scheme the integerized policy approximates — the
// spectrally-tuned SOS recurrence must reach balance in no more rounds
// than first-order diffusion with the same α.  Checked with the
// *production* coefficients from `SosParams::for_topology` on random
// rings and tori, the poorly-conditioned shapes the scheme targets.
// ---------------------------------------------------------------------

fn gen_diffusion_shape(g: &mut Gen) -> TopoCase {
    if g.bool() {
        let len = g.usize_in(6..17).max(6);
        TopoCase { topo: Topology::Ring { len }, p: len }
    } else {
        let rows = g.usize_in(3..6).max(3);
        let cols = g.usize_in(3..6).max(3);
        TopoCase { topo: Topology::Torus { rows, cols }, p: rows * cols }
    }
}

/// Rounds of the continuous diffusion iteration until every rank is within
/// 0.5 tasks of the mean, starting from a 1000-task spike at rank 0.
/// `second_order = false` runs w(t+1) = M·w(t); `true` runs the SOS
/// recurrence w(t+1) = β·M·w(t) + (1−β)·w(t−1), seeded with one plain step
/// exactly as the policy seeds its zeroed flow memory.
fn rounds_to_balance(topo: &Topology, p: usize, second_order: bool) -> usize {
    let params = SosParams::for_topology(topo, p);
    let nbrs: Vec<Vec<usize>> = (0..p)
        .map(|i| topo.neighbors(ProcessId(i as u32), p).iter().map(|q| q.idx()).collect())
        .collect();
    let step = |w: &[f64]| -> Vec<f64> {
        (0..p)
            .map(|i| {
                let s: f64 = nbrs[i].iter().map(|&j| w[j] - w[i]).sum();
                w[i] + params.alpha * s
            })
            .collect()
    };
    let mut prev = vec![0.0f64; p];
    prev[0] = 1000.0;
    let mean = 1000.0 / p as f64;
    let balanced = |w: &[f64]| w.iter().all(|&x| (x - mean).abs() < 0.5);
    if balanced(&prev) {
        return 0;
    }
    let mut cur = step(&prev);
    for round in 1..=10_000 {
        if balanced(&cur) {
            return round;
        }
        let next: Vec<f64> = if second_order {
            let m = step(&cur);
            (0..p).map(|i| params.beta * m[i] + (1.0 - params.beta) * prev[i]).collect()
        } else {
            step(&cur)
        };
        prev = std::mem::replace(&mut cur, next);
    }
    usize::MAX
}

#[test]
fn prop_sos_balances_in_no_more_rounds_than_fos() {
    forall(16, 0x505F, gen_diffusion_shape, |c| -> Result<(), String> {
        let fos = rounds_to_balance(&c.topo, c.p, false);
        let sos = rounds_to_balance(&c.topo, c.p, true);
        if fos == usize::MAX {
            return Err(format!("{c:?}: first-order iteration never balanced"));
        }
        if sos > fos {
            return Err(format!("{c:?}: second-order took {sos} rounds vs first-order {fos}"));
        }
        Ok(())
    });
}

#[test]
fn prop_workload_trace_monotone_time() {
    forall(25, 0x7EA7, gen_scenario, |s| -> Result<(), String> {
        let r = SimEngine::from_config(&config_of(s), build_graph(s))
            .run()
            .map_err(|e| format!("{e}"))?;
        for (i, tr) in r.traces.per_process.iter().enumerate() {
            let mut prev = f64::NEG_INFINITY;
            for &(t, _) in tr.samples() {
                if t < prev {
                    return Err(format!("{s:?}: p{i} trace time went backwards"));
                }
                prev = t;
            }
        }
        Ok(())
    });
}
