//! End-to-end integration tests: the full stack from config to verified
//! numerics, in both execution modes.
//!
//! Real-mode tests need built artifacts (`make artifacts`); they self-skip
//! with a notice when `artifacts/manifest.txt` is absent.

use ductr::cholesky;
use ductr::config::{Config, Grid, Strategy};
use ductr::dlb::threshold::calibrate_from_traces;
use ductr::experiments::{fig1, fig3, fig4, fig5, sec4};

fn artifacts_present() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.txt").exists()
}

fn sim_cfg() -> Config {
    let mut c = Config::default();
    c.processes = 10;
    c.grid = Some(Grid::new(2, 5));
    c.nb = 12;
    c.block = 256;
    c.wt = 5;
    c.delta = 0.005;
    c.validate().expect("valid");
    c
}

// -------------------------------------------------------------------------
// simulated mode
// -------------------------------------------------------------------------

#[test]
fn sim_cholesky_completes_all_tasks() {
    let mut cfg = sim_cfg();
    cfg.dlb_enabled = false;
    let r = cholesky::run_sim(&cfg).expect("sim");
    assert_eq!(r.tasks, 12 + 2 * 66 + 220);
    assert!(r.makespan > 0.0);
    // every process's queue drained (trace ends at 0)
    for tr in &r.traces.per_process {
        let last = tr.samples().last().expect("sampled");
        assert_eq!(last.1, 0, "queue must drain");
    }
}

#[test]
fn sim_cholesky_dlb_strategies_all_terminate() {
    for strategy in [Strategy::Basic, Strategy::Equalizing, Strategy::Smart] {
        let mut cfg = sim_cfg();
        cfg.dlb_enabled = true;
        cfg.strategy = strategy;
        let r = cholesky::run_sim(&cfg)
            .unwrap_or_else(|e| panic!("strategy {strategy} failed: {e}"));
        assert!(r.makespan > 0.0, "{strategy}");
    }
}

#[test]
fn sim_paper_protocol_fig4_left_shape() {
    // Fig 4 left at paper scale in the DES: N=20000, 12×12 blocks, 2×5 grid.
    // Shape target: DLB does not hurt, and migrations happen.
    let spec = &fig4::CASES[0];
    let r = fig4::run_case(spec, 1).expect("fig4 case");
    assert!(r.calibrated_wt >= 1);
    assert!(r.on.counters.tasks_exported > 0, "expected migrations");
    assert!(
        r.improvement() > -0.05,
        "DLB must not substantially hurt: {:+.2}%",
        r.improvement() * 100.0
    );
}

#[test]
fn sim_export_import_bookkeeping_consistent() {
    let mut cfg = sim_cfg();
    cfg.dlb_enabled = true;
    let r = cholesky::run_sim(&cfg).expect("sim");
    assert_eq!(
        r.counters.tasks_exported, r.counters.tasks_received,
        "global export/import accounting must balance"
    );
}

#[test]
fn wt_calibration_rule() {
    let mut cfg = sim_cfg();
    cfg.dlb_enabled = false;
    let r = cholesky::run_sim(&cfg).expect("sim");
    let wt = calibrate_from_traces(&r.traces);
    assert_eq!(wt, (r.traces.max_workload() / 2).max(1));
}

// -------------------------------------------------------------------------
// real (threaded + PJRT) mode
// -------------------------------------------------------------------------

#[test]
fn real_cholesky_verifies_numerically() {
    if !artifacts_present() {
        eprintln!("skipping real-mode test: run `make artifacts` first");
        return;
    }
    let mut cfg = Config::default();
    cfg.processes = 4;
    cfg.grid = Some(Grid::new(2, 2));
    cfg.nb = 6;
    cfg.block = 32;
    cfg.dlb_enabled = false;
    cfg.net_latency = 0.0;
    cfg.validate().expect("valid");
    let r = cholesky::run_real(&cfg).expect("real run");
    let res = r.residual.expect("residual computed");
    assert!(res < 1e-4, "L·Lᵀ ≈ A must hold, residual = {res:.3e}");
}

#[test]
fn real_cholesky_with_dlb_still_correct() {
    if !artifacts_present() {
        eprintln!("skipping real-mode test: run `make artifacts` first");
        return;
    }
    let mut cfg = Config::default();
    cfg.processes = 5;
    cfg.grid = Some(Grid::new(1, 5)); // deliberately imbalanced column grid
    cfg.nb = 8;
    cfg.block = 32;
    cfg.dlb_enabled = true;
    cfg.strategy = Strategy::Basic;
    cfg.wt = 2;
    cfg.delta = 0.002;
    cfg.net_latency = 0.0;
    cfg.validate().expect("valid");
    let r = cholesky::run_real(&cfg).expect("real run");
    let res = r.residual.expect("residual computed");
    assert!(res < 1e-4, "DLB must not corrupt numerics: residual = {res:.3e}");
    // the imbalanced grid should trigger at least some pairing activity
    assert!(r.counters.rounds > 0, "expected DLB searches");
}

#[test]
fn real_matches_sim_task_structure() {
    if !artifacts_present() {
        eprintln!("skipping real-mode test: run `make artifacts` first");
        return;
    }
    let mut cfg = Config::default();
    cfg.processes = 4;
    cfg.grid = Some(Grid::new(2, 2));
    cfg.nb = 5;
    cfg.block = 32;
    cfg.dlb_enabled = false;
    cfg.validate().expect("valid");
    let real = cholesky::run_real(&cfg).expect("real");
    let sim = cholesky::run_sim(&cfg).expect("sim");
    assert_eq!(real.tasks, sim.tasks);
}

// -------------------------------------------------------------------------
// experiment drivers (scaled)
// -------------------------------------------------------------------------

#[test]
fn experiment_fig1_smoke() {
    let r = fig1::run(6, 500, 3);
    assert_eq!(r.curves.len(), 10);
    assert!(r.k_half_n5 > 0.96);
}

#[test]
fn experiment_fig3_smoke() {
    let r = fig3::run(&[8, 16], &[0.5], 0.01, 4, 3);
    assert_eq!(r.cells.len(), 2);
    assert!(r.cells.iter().all(|c| c.mean > 0.0));
}

#[test]
fn experiment_fig5_scaled_smoke() {
    let r = fig5::run(2200, &[1, 2, 3]).expect("fig5");
    assert_eq!(r.outcomes.len(), 3);
}

#[test]
fn experiment_sec4_smoke() {
    let r = sec4::run(4).expect("sec4");
    assert!(!r.table.is_empty());
    assert_eq!(r.cases.len(), 2);
}
