"""Shared pytest fixtures for the kernel/model test suite."""

from __future__ import annotations

import os
import sys

import numpy as np
import pytest

# Run `pytest tests/` from the python/ directory; make `compile` importable
# regardless of invocation cwd.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# Enable x64 so the f64 sweeps exercise a second dtype path.
import jax

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(20180507)


def make_spd(n: int, dtype, seed: int = 0):
    """Well-conditioned SPD block for POTRF/TRSM tests."""
    r = np.random.default_rng(seed)
    m = r.standard_normal((n, n)).astype(dtype)
    return m @ m.T + n * np.eye(n, dtype=dtype)
