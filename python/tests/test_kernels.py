"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; fixed-case tests pin the exact AOT
shapes.  Tolerances are dtype-aware: f32 kernels accumulate in f32, so the
bound scales with the reduction length.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import kernels
from compile.kernels import ref
from tests.conftest import make_spd

DTYPES = [np.float32, np.float64]


def tol(dtype, n):
    eps = np.finfo(dtype).eps
    return 60 * eps * max(n, 1)


def assert_close(actual, expected, dtype, n, label):
    t = tol(dtype, n)
    scale = max(1.0, float(np.max(np.abs(expected))))
    np.testing.assert_allclose(
        np.asarray(actual), np.asarray(expected), rtol=t, atol=t * scale, err_msg=label
    )


# --------------------------------------------------------------------------
# pick_tile
# --------------------------------------------------------------------------


class TestPickTile:
    @given(st.integers(min_value=1, max_value=4096))
    def test_divides(self, n):
        t = kernels.pick_tile(n)
        assert n % t == 0
        assert 1 <= t <= max(n, 1)

    @given(st.integers(min_value=1, max_value=4096), st.integers(min_value=1, max_value=256))
    def test_respects_cap_for_pow2(self, n, cap):
        t = kernels.pick_tile(n, cap)
        # power-of-two tiles never exceed the cap; odd fallback may equal n
        if t & (t - 1) == 0 and t != n:
            assert t <= cap

    def test_exact_values(self):
        assert kernels.pick_tile(128) == 64  # capped
        assert kernels.pick_tile(128, cap=128) == 128
        assert kernels.pick_tile(96) == 32
        assert kernels.pick_tile(7) == 7  # odd fallback: single tile
        assert kernels.pick_tile(1) == 1

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            kernels.pick_tile(0)


# --------------------------------------------------------------------------
# POTRF
# --------------------------------------------------------------------------


class TestPotrf:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n", [1, 2, 3, 8, 16, 32, 64])
    def test_matches_oracle(self, dtype, n):
        a = make_spd(n, dtype, seed=n)
        assert_close(kernels.potrf(a), ref.potrf(a), dtype, n, f"potrf n={n}")

    @pytest.mark.parametrize("n", [8, 32])
    def test_upper_triangle_zero(self, n):
        a = make_spd(n, np.float32, seed=n)
        l = np.asarray(kernels.potrf(a))
        assert np.all(np.triu(l, 1) == 0.0)

    @pytest.mark.parametrize("n", [8, 32])
    def test_reconstructs(self, n):
        a = make_spd(n, np.float64, seed=n + 1)
        l = np.asarray(kernels.potrf(a))
        assert_close(l @ l.T, a, np.float64, n, "L·Lᵀ = A")

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=48),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, n, seed):
        a = make_spd(n, np.float64, seed=seed)
        assert_close(kernels.potrf(a), ref.potrf(a), np.float64, n, f"potrf n={n}")

    def test_rejects_nonsquare(self):
        with pytest.raises(ValueError):
            kernels.potrf(np.zeros((4, 8), np.float32))


# --------------------------------------------------------------------------
# TRSM
# --------------------------------------------------------------------------


class TestTrsm:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n", [1, 2, 4, 16, 32, 64])
    def test_matches_oracle(self, dtype, n):
        l = np.asarray(ref.potrf(make_spd(n, dtype, seed=n)))
        b = np.random.default_rng(n).standard_normal((n, n)).astype(dtype)
        assert_close(kernels.trsm(l, b), ref.trsm(l, b), dtype, n, f"trsm n={n}")

    @pytest.mark.parametrize("n", [16])
    def test_solves_equation(self, n):
        """X · Lᵀ = B must hold exactly up to roundoff."""
        l = np.asarray(ref.potrf(make_spd(n, np.float64, seed=3)))
        b = np.random.default_rng(3).standard_normal((n, n))
        x = np.asarray(kernels.trsm(l, b))
        assert_close(x @ l.T, b, np.float64, n, "X·Lᵀ = B")

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.integers(min_value=1, max_value=40),
        n=st.integers(min_value=1, max_value=40),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_rectangular_rhs(self, m, n, seed):
        """B may be m×n with L n×n (DAG panels are square, kernel is general)."""
        l = np.asarray(ref.potrf(make_spd(n, np.float64, seed=seed)))
        b = np.random.default_rng(seed).standard_normal((m, n))
        assert_close(kernels.trsm(l, b), ref.trsm(l, b), np.float64, max(m, n), "trsm rect")

    def test_rejects_mismatched(self):
        with pytest.raises(ValueError):
            kernels.trsm(np.eye(4, dtype=np.float32), np.zeros((4, 8), np.float32))


# --------------------------------------------------------------------------
# SYRK / GEMM
# --------------------------------------------------------------------------


class TestUpdates:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n", [1, 2, 8, 32, 64, 128])
    def test_syrk_matches(self, dtype, n):
        r = np.random.default_rng(n)
        c = r.standard_normal((n, n)).astype(dtype)
        a = r.standard_normal((n, n)).astype(dtype)
        assert_close(kernels.syrk(c, a), ref.syrk(c, a), dtype, n, f"syrk n={n}")

    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n", [1, 2, 8, 32, 64, 128])
    def test_gemm_matches(self, dtype, n):
        r = np.random.default_rng(n + 7)
        c = r.standard_normal((n, n)).astype(dtype)
        a = r.standard_normal((n, n)).astype(dtype)
        b = r.standard_normal((n, n)).astype(dtype)
        assert_close(kernels.gemm(c, a, b), ref.gemm(c, a, b), dtype, n, f"gemm n={n}")

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 24, 32, 64]),
        n=st.sampled_from([8, 16, 24, 32, 64]),
        k=st.sampled_from([8, 16, 24, 32, 64]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_gemm_rectangular(self, m, n, k, seed):
        r = np.random.default_rng(seed)
        c = r.standard_normal((m, n))
        a = r.standard_normal((m, k))
        b = r.standard_normal((n, k))
        assert_close(kernels.gemm(c, a, b), ref.gemm(c, a, b), np.float64, k, "gemm rect")

    @pytest.mark.parametrize("tile", [8, 16, 32, 64])
    def test_gemm_tile_invariance(self, tile):
        """Result must not depend on the chosen tile size."""
        r = np.random.default_rng(0)
        n = 64
        c = r.standard_normal((n, n)).astype(np.float32)
        a = r.standard_normal((n, n)).astype(np.float32)
        b = r.standard_normal((n, n)).astype(np.float32)
        assert_close(
            kernels.gemm(c, a, b, tile=tile), ref.gemm(c, a, b), np.float32, n, f"tile={tile}"
        )

    def test_gemm_rejects_bad_tile(self):
        with pytest.raises(ValueError):
            kernels.gemm(
                np.zeros((64, 64), np.float32),
                np.zeros((64, 64), np.float32),
                np.zeros((64, 64), np.float32),
                tile=48,
            )

    def test_gemm_rejects_shape_mismatch(self):
        with pytest.raises(ValueError):
            kernels.gemm(
                np.zeros((8, 8), np.float32),
                np.zeros((8, 4), np.float32),
                np.zeros((4, 8), np.float32),
            )

    def test_syrk_rejects_nonsquare_c(self):
        with pytest.raises(ValueError):
            kernels.syrk(np.zeros((4, 8), np.float32), np.zeros((4, 4), np.float32))


# --------------------------------------------------------------------------
# GEMV
# --------------------------------------------------------------------------


class TestGemv:
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n", [1, 8, 32, 64, 256])
    def test_matches_oracle(self, dtype, n):
        r = np.random.default_rng(n + 13)
        a = r.standard_normal((n, n)).astype(dtype)
        x = r.standard_normal(n).astype(dtype)
        assert_close(kernels.gemv(a, x), ref.gemv(a, x), dtype, n, f"gemv n={n}")

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([4, 8, 16, 32, 128]),
        k=st.sampled_from([4, 8, 16, 32, 128]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_rectangular(self, m, k, seed):
        r = np.random.default_rng(seed)
        a = r.standard_normal((m, k))
        x = r.standard_normal(k)
        assert_close(kernels.gemv(a, x), ref.gemv(a, x), np.float64, k, "gemv rect")

    def test_rejects_mismatched(self):
        with pytest.raises(ValueError):
            kernels.gemv(np.zeros((8, 8), np.float32), np.zeros(4, np.float32))
