"""L2 correctness: the blocked task composition reproduces dense Cholesky.

This validates the *same* task algebra the Rust coordinator executes
(cholesky/dag.rs) — if these pass, any numeric error on the Rust side is in
the runtime plumbing, not the math.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from tests.conftest import make_spd


class TestSplitAssemble:
    @pytest.mark.parametrize("nb,b", [(1, 4), (2, 3), (3, 8), (4, 16)])
    def test_roundtrip(self, nb, b):
        a = np.random.default_rng(0).standard_normal((nb * b, nb * b))
        blocks = model.split(jnp.asarray(a), nb)
        assert blocks.shape == (nb, nb, b, b)
        back = model.assemble(blocks)
        np.testing.assert_array_equal(np.asarray(back), a)

    def test_block_content(self):
        nb, b = 2, 2
        a = jnp.arange(16.0).reshape(4, 4)
        blocks = model.split(a, nb)
        np.testing.assert_array_equal(np.asarray(blocks[0, 1]), np.asarray(a[0:2, 2:4]))
        np.testing.assert_array_equal(np.asarray(blocks[1, 0]), np.asarray(a[2:4, 0:2]))


class TestBlockCholesky:
    @pytest.mark.parametrize("nb,b", [(1, 8), (2, 8), (3, 8), (4, 4), (4, 16), (6, 8)])
    def test_matches_dense(self, nb, b):
        n = nb * b
        a = jnp.asarray(make_spd(n, np.float64, seed=nb * 100 + b))
        lb = model.block_cholesky(model.split(a, nb))
        l = np.asarray(model.assemble(lb))
        lref = np.linalg.cholesky(np.asarray(a))
        np.testing.assert_allclose(np.tril(l), lref, rtol=1e-9, atol=1e-9)

    @settings(max_examples=10, deadline=None)
    @given(
        nb=st.integers(min_value=1, max_value=5),
        b=st.sampled_from([4, 8, 12]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, nb, b, seed):
        n = nb * b
        a = jnp.asarray(make_spd(n, np.float64, seed=seed))
        lb = model.block_cholesky(model.split(a, nb))
        l = np.tril(np.asarray(model.assemble(lb)))
        np.testing.assert_allclose(l @ l.T, np.asarray(a), rtol=1e-8, atol=1e-8)

    def test_f32_stays_accurate(self):
        nb, b = 4, 16
        a = jnp.asarray(make_spd(nb * b, np.float32, seed=5))
        lb = model.block_cholesky(model.split(a, nb))
        l = np.tril(np.asarray(model.assemble(lb)))
        rel = np.abs(l @ l.T - np.asarray(a)).max() / np.abs(np.asarray(a)).max()
        assert rel < 1e-4


class TestTaskSpecs:
    """§4 metadata invariants — mirrored in rust/src/dlb/costmodel.rs."""

    def test_gemm_intensity(self):
        """Paper §4: block GEMM has F = 2m³, D = 3m²(+out) → Q = O(1/m)."""
        spec = model.TASKS["gemm"]
        for m in (32, 64, 128):
            assert spec.flops(m) == 2 * m**3
            assert spec.doubles_moved(m) == 4 * m * m  # 3 inputs + 1 output

    def test_gemv_intensity(self):
        """Paper §4: GEMV has F = 2m², D = m²(+x+y) → Q ≈ S/R/2 = 20."""
        spec = model.TASKS["gemv"]
        for m in (32, 64, 128):
            assert spec.flops(m) == 2 * m**2
            assert spec.doubles_moved(m) == m * m + 2 * m

    def test_q_ratio_matches_paper(self):
        """With S/R = 40: Q_gemm ≈ 80/m (4m²·40/2m³); paper's 3m² variant gives 60/m.

        We count the output return too (4m² total); the paper counts D = 3m².
        Both say: negligible for large m.  Q_gemv → 40·(m²+2m)/2m² → ≈ 20.
        """
        s_over_r = 40.0
        gemm = model.TASKS["gemm"]
        m = 1000
        q_gemm = s_over_r * gemm.doubles_moved(m) / gemm.flops(m)
        assert q_gemm < 0.1
        gemv = model.TASKS["gemv"]
        q_gemv = s_over_r * gemv.doubles_moved(m) / gemv.flops(m)
        assert abs(q_gemv - 20.0) < 0.5

    @pytest.mark.parametrize("name", list(model.TASKS))
    def test_arity_matches_shapes(self, name):
        spec = model.TASKS[name]
        assert len(spec.arg_shapes(16)) == spec.arity

    @pytest.mark.parametrize("name", list(model.TASKS))
    def test_flops_positive_monotone(self, name):
        spec = model.TASKS[name]
        vals = [spec.flops(b) for b in (8, 16, 32, 64)]
        assert all(v > 0 for v in vals)
        assert vals == sorted(vals)
