"""AOT pipeline: HLO-text lowering and manifest emission.

These tests lower real modules (slow-ish) so they use the smallest block and
assert structural properties the Rust loader depends on.
"""

from __future__ import annotations

import os

import pytest

from compile import aot, model


class TestLowering:
    @pytest.mark.parametrize("name", list(model.TASKS))
    def test_lowers_to_hlo_text(self, name):
        text = aot.lower_task(model.TASKS[name], 8)
        assert "HloModule" in text
        assert "ROOT" in text
        # return_tuple=True → root is a tuple; the Rust side calls to_tuple1.
        assert "tuple(" in text or "(f32[" in text

    def test_entry_params_match_arity(self):
        text = aot.lower_task(model.TASKS["gemm"], 8)
        params = [l for l in text.splitlines() if "parameter(" in l and "f32[8,8]" in l]
        assert len(params) >= 3

    def test_shape_str(self):
        assert aot.shape_str((8, 8)) == "8x8"
        assert aot.shape_str((8,)) == "8"


class TestEmit:
    def test_emit_writes_manifest_and_files(self, tmp_path):
        out = str(tmp_path / "artifacts")
        aot.emit(out, [8], verify=False)
        manifest = open(os.path.join(out, "manifest.txt")).read().splitlines()
        data_lines = [l for l in manifest if l.startswith("kernel ")]
        assert len(data_lines) == len(model.TASKS)
        for line in data_lines:
            parts = line.split()
            # kernel <name> <block> <path> <arity> <dtype> <shapes...> <F> <D>
            assert parts[0] == "kernel"
            name, block, path, arity = parts[1], int(parts[2]), parts[3], int(parts[4])
            assert name in model.TASKS
            assert block == 8
            assert os.path.exists(os.path.join(out, path))
            assert arity == model.TASKS[name].arity
            flops, doubles = int(parts[-2]), int(parts[-1])
            assert flops == model.TASKS[name].flops(8)
            assert doubles == model.TASKS[name].doubles_moved(8)

    def test_version_line_present(self, tmp_path):
        out = str(tmp_path / "artifacts")
        aot.emit(out, [8], verify=False)
        lines = open(os.path.join(out, "manifest.txt")).read().splitlines()
        assert any(l.strip() == "version 1" for l in lines)

    def test_main_rejects_bad_blocks(self):
        with pytest.raises(SystemExit):
            aot.main(["--out", "/tmp/x", "--blocks", "-4"])
