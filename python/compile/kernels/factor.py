"""Sequential panel kernels: POTRF (unblocked Cholesky) and TRSM.

Both operate on one VMEM-resident block — they are the latency-bound,
inherently sequential kernels of a blocked factorization, so there is no
grid: the whole block is a single Pallas invocation and the column recurrence
runs as a ``lax.fori_loop`` inside the kernel.

The column updates are written in masked-vector form (no data-dependent
dynamic slices beyond a single column scatter), which keeps the interpret-mode
lowering to plain HLO and maps onto the TPU VPU as full-lane vector ops.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import common


def _chol_unblocked(a):
    """Cholesky–Banachiewicz with masked column updates.

    Column j of L:  c = a[:, j] − L · (row j of L restricted to cols < j);
    then l[j, j] = sqrt(c[j]) and l[i, j] = c[i] / l[j, j] for i > j.
    """
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(j, l):
        mask = (idx < j).astype(a.dtype)
        lj = l[j, :] * mask  # row j of L, columns < j
        c = a[:, j] - l @ lj
        d = jnp.sqrt(c[j])
        col = jnp.where(idx == j, d, jnp.where(idx > j, c / d, jnp.zeros_like(c)))
        return l.at[:, j].set(col)

    return lax.fori_loop(0, n, body, jnp.zeros_like(a))


def _trsm_unblocked(l, b):
    """Solve X·Lᵀ = B by forward substitution over columns of X.

    x[:, j] = (b[:, j] − X[:, :j] · L[j, :j]ᵀ) / l[j, j]
    """
    n = l.shape[0]
    idx = jnp.arange(n)

    def body(j, x):
        mask = (idx < j).astype(b.dtype)
        lj = l[j, :] * mask
        c = b[:, j] - x @ lj
        return x.at[:, j].set(c / l[j, j])

    return lax.fori_loop(0, n, body, jnp.zeros_like(b))


def _potrf_kernel(a_ref, l_ref):
    l_ref[...] = _chol_unblocked(a_ref[...])


def _trsm_kernel(l_ref, b_ref, x_ref):
    x_ref[...] = _trsm_unblocked(l_ref[...], b_ref[...])


def potrf(a):
    """Pallas POTRF: lower Cholesky factor of one SPD block (upper zeroed)."""
    common.check_square("potrf", a)
    return pl.pallas_call(
        _potrf_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a)


def trsm(l, b):
    """Pallas TRSM: X with X·Lᵀ = B, L lower-triangular."""
    common.check_square("trsm", l)
    if b.shape[1] != l.shape[0]:
        raise ValueError(f"trsm: B cols {b.shape[1]} != L order {l.shape[0]}")
    return pl.pallas_call(
        _trsm_kernel,
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=True,
    )(l, b)
