"""Shared helpers for the Pallas kernel layer (L1).

Everything here is build-time only: kernels are AOT-lowered to HLO text by
``compile/aot.py`` and executed from Rust via PJRT.  Pallas is always invoked
with ``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode (which lowers to plain HLO) is the portable
path.  See DESIGN.md §3 for the TPU tiling rationale.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Tile-size cap for the MXU-oriented tiling.  On a real TPU the MXU wants
#: multiples of (8, 128) for f32; on the laptop-scale AOT shapes we cap at 64
#: so that the common block sizes (32/64/128/256) tile evenly.
DEFAULT_TILE_CAP = 64


def pick_tile(n: int, cap: int = DEFAULT_TILE_CAP) -> int:
    """Largest power-of-two divisor of ``n`` that is ``<= cap``.

    Guarantees ``n % pick_tile(n) == 0`` so BlockSpecs tile exactly; falls
    back to ``n`` itself when ``n`` has no power-of-two factor (odd sizes),
    i.e. the kernel runs as a single tile.
    """
    if n <= 0:
        raise ValueError(f"tile target must be positive, got {n}")
    best = 1
    t = 1
    while t <= min(n, cap):
        if n % t == 0:
            best = t
        t *= 2
    if best == 1 and n <= cap:
        return n
    return best


def supported_dtype(dtype) -> bool:
    """Dtypes the kernels are tested against (f32 always; f64 when x64 on)."""
    return jnp.dtype(dtype) in (jnp.dtype(jnp.float32), jnp.dtype(jnp.float64))


def check_square(name: str, x) -> None:
    if x.ndim != 2 or x.shape[0] != x.shape[1]:
        raise ValueError(f"{name}: expected a square 2-D block, got {x.shape}")


def check_same_shape(name: str, *xs) -> None:
    shapes = {tuple(x.shape) for x in xs}
    if len(shapes) != 1:
        raise ValueError(f"{name}: blocks must share a shape, got {shapes}")
