"""Pure-jnp oracle implementations for every L1 kernel.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels match
these to tight tolerances.  The Rust side additionally validates the whole
factorization numerically (L·Lᵀ ≈ A).

Signatures mirror the task types of the right-looking block Cholesky
(paper §5, Fig 2):

- ``potrf(a)``        → lower Cholesky factor of the diagonal block
- ``trsm(l, b)``      → X with X·Lᵀ = B   (panel update below the diagonal)
- ``syrk(c, a)``      → C − A·Aᵀ          (trailing diagonal update)
- ``gemm(c, a, b)``   → C − A·Bᵀ          (trailing off-diagonal update)
- ``gemv(a, x)``      → A·x               (§4 low-intensity comparison task)
"""

from __future__ import annotations

import jax.numpy as jnp
import jax.scipy.linalg as jsla


def potrf(a):
    """Lower Cholesky factor with explicit zero upper triangle."""
    return jnp.tril(jnp.linalg.cholesky(a))


def trsm(l, b):
    """Solve X · Lᵀ = B for X (right-side, lower-triangular, transposed)."""
    # solve L · Xᵀ = Bᵀ  →  X = (L⁻¹ Bᵀ)ᵀ
    return jsla.solve_triangular(l, b.T, lower=True).T


def syrk(c, a):
    """Symmetric rank-k update C − A·Aᵀ (full block; callers use the lower part)."""
    return c - a @ a.T


def gemm(c, a, b):
    """General update C − A·Bᵀ."""
    return c - a @ b.T


def gemv(a, x):
    """Matrix–vector product A·x."""
    return a @ x
