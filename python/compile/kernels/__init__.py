"""L1 Pallas kernels for the block Cholesky task types (+ §4's GEMV).

Public surface re-exported here; the pure-jnp oracles live in ``ref``.
"""

from .factor import potrf, trsm  # noqa: F401
from .update import gemm, gemv, syrk  # noqa: F401
from . import ref  # noqa: F401
from .common import DEFAULT_TILE_CAP, pick_tile  # noqa: F401

__all__ = [
    "potrf",
    "trsm",
    "syrk",
    "gemm",
    "gemv",
    "ref",
    "pick_tile",
    "DEFAULT_TILE_CAP",
]
