"""Throughput kernels: tiled GEMM / SYRK trailing updates and GEMV.

These are the MXU-facing kernels.  The HBM↔VMEM schedule the paper's CPU
implementation got from the BLAS is expressed here with ``BlockSpec``s:

- grid = (M/bm, N/bn, K/bk); the (i, j) output tile stays resident in the
  output ref while the k axis sweeps, i.e. a classic accumulate-in-VMEM
  matmul.  The output BlockSpec's index map ignores the k axis, which is what
  pins the tile.
- tiles are square powers of two capped at ``common.DEFAULT_TILE_CAP``; on a
  real TPU bm×bk, bk×bn, bm×bn ≤ 64² f32 = 16 KiB each, three orders of
  magnitude under VMEM, leaving room for double-buffered prefetch.

Semantics (see ref.py):  gemm(c,a,b) = c − a·bᵀ,  syrk(c,a) = c − a·aᵀ,
gemv(a,x) = a·x.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _gemm_kernel(c_ref, a_ref, b_ref, o_ref):
    """o(i,j) = c(i,j) − Σ_k a(i,k) · b(j,k)ᵀ, accumulated over the k axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] = o_ref[...] - a_ref[...] @ b_ref[...].T


def gemm(c, a, b, tile: int | None = None):
    """Pallas tiled GEMM update: C − A·Bᵀ.

    Shapes: c (m, n), a (m, k), b (n, k).  ``tile`` overrides the automatic
    square tile choice (must divide all three dims).
    """
    m, n = c.shape
    am, k = a.shape
    bn, bk = b.shape
    if am != m or bn != n or bk != k:
        raise ValueError(f"gemm: inconsistent shapes c{c.shape} a{a.shape} b{b.shape}")
    tm = tile or common.pick_tile(m)
    tn = tile or common.pick_tile(n)
    tk = tile or common.pick_tile(k)
    if m % tm or n % tn or k % tk:
        raise ValueError(f"gemm: tile ({tm},{tn},{tk}) does not divide ({m},{n},{k})")
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tn, tk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(c, a, b)


def syrk(c, a, tile: int | None = None):
    """Pallas SYRK update: C − A·Aᵀ (computed as gemm with b = a).

    The symmetric saving (skip upper tiles) is a real-TPU optimization; in
    interpret mode we keep the full computation so the artifact matches the
    oracle block-for-block.  Shapes: c (n, n), a (n, k).
    """
    common.check_square("syrk", c)
    if a.shape[0] != c.shape[0]:
        raise ValueError(f"syrk: a rows {a.shape[0]} != c order {c.shape[0]}")
    return gemm(c, a, a, tile=tile)


def _gemv_kernel(a_ref, x_ref, o_ref):
    """o(i) = Σ_k a(i,k) · x(k), accumulated over the k axis."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] = o_ref[...] + a_ref[...] @ x_ref[...]


def gemv(a, x, tile: int | None = None):
    """Pallas tiled GEMV: A·x with row-tile grid and k accumulation."""
    m, k = a.shape
    if x.shape != (k,):
        raise ValueError(f"gemv: x shape {x.shape} != ({k},)")
    tm = tile or common.pick_tile(m)
    tk = tile or common.pick_tile(k)
    if m % tm or k % tk:
        raise ValueError(f"gemv: tile ({tm},{tk}) does not divide ({m},{k})")
    grid = (m // tm, k // tk)
    return pl.pallas_call(
        _gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, kk: (i, kk)),
            pl.BlockSpec((tk,), lambda i, kk: (kk,)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i, kk: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=True,
    )(a, x)
