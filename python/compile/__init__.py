"""Build-time compile package: L2 model + L1 kernels + AOT lowering.

Nothing in this package is imported at runtime; the Rust coordinator only
consumes the HLO-text artifacts emitted by ``python -m compile.aot``.
"""
