"""L2: the JAX compute graph for each DuctTeip task type.

Each of the paper's Cholesky task types (Fig 2) — POTRF, TRSM, SYRK, GEMM —
plus the §4 GEMV comparison task is a jitted JAX function that calls the L1
Pallas kernel.  ``compile/aot.py`` lowers each one per block size to HLO text
for the Rust PJRT runtime.

This module also contains ``block_cholesky``: the full right-looking blocked
factorization composed from the task functions.  It is never shipped to Rust
(the Rust coordinator *is* the composition — it builds the task DAG and runs
one artifact per task); it exists to validate at build time that the task
algebra reproduces ``jnp.linalg.cholesky`` exactly, and to serve as the L2
fusion-audit target for the §Perf pass.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from . import kernels


# --------------------------------------------------------------------------
# Task functions (one per DuctTeip task type)
# --------------------------------------------------------------------------


@jax.jit
def potrf_task(a):
    """Factorize a diagonal block: A[j,j] → L[j,j]."""
    return kernels.potrf(a)


@jax.jit
def trsm_task(l, b):
    """Panel update: A[i,j] → A[i,j] · L[j,j]⁻ᵀ."""
    return kernels.trsm(l, b)


@jax.jit
def syrk_task(c, a):
    """Trailing diagonal update: A[i,i] −= A[i,j] · A[i,j]ᵀ."""
    return kernels.syrk(c, a)


@jax.jit
def gemm_task(c, a, b):
    """Trailing off-diagonal update: A[i,k] −= A[i,j] · A[k,j]ᵀ."""
    return kernels.gemm(c, a, b)


@jax.jit
def gemv_task(a, x):
    """§4 low-intensity task: y = A·x."""
    return kernels.gemv(a, x)


# --------------------------------------------------------------------------
# Task metadata — must stay in sync with rust/src/dlb/costmodel.rs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class TaskSpec:
    """AOT metadata for one (task type, block size) artifact.

    ``flops``/``doubles_moved`` implement the paper's §4 F and D for the
    task: F = floating point ops, D = doubles that must cross the network to
    run the task remotely (inputs shipped + output returned).
    """

    name: str
    arity: int
    fn: object

    def arg_shapes(self, b: int) -> list[tuple[int, ...]]:
        if self.name == "potrf":
            return [(b, b)]
        if self.name == "trsm":
            return [(b, b), (b, b)]
        if self.name == "syrk":
            return [(b, b), (b, b)]
        if self.name == "gemm":
            return [(b, b), (b, b), (b, b)]
        if self.name == "gemv":
            return [(b, b), (b,)]
        raise KeyError(self.name)

    def flops(self, b: int) -> int:
        # Standard LAPACK op counts for square b×b blocks.
        if self.name == "potrf":
            return b**3 // 3
        if self.name == "trsm":
            return b**3
        if self.name == "syrk":
            return b**3  # b² rows × b cols × b MACs (full block, see kernel)
        if self.name == "gemm":
            return 2 * b**3
        if self.name == "gemv":
            return 2 * b**2
        raise KeyError(self.name)

    def doubles_moved(self, b: int) -> int:
        # Σ inputs + output, in elements (paper counts doubles; we emit f32
        # artifacts but keep the element count — §4's Q only uses the ratio).
        shapes = self.arg_shapes(b)
        out = b if self.name == "gemv" else b * b
        return sum(int(jnp.prod(jnp.array(s))) for s in shapes) + out


TASKS: dict[str, TaskSpec] = {
    "potrf": TaskSpec("potrf", 1, potrf_task),
    "trsm": TaskSpec("trsm", 2, trsm_task),
    "syrk": TaskSpec("syrk", 2, syrk_task),
    "gemm": TaskSpec("gemm", 3, gemm_task),
    "gemv": TaskSpec("gemv", 2, gemv_task),
}


# --------------------------------------------------------------------------
# Build-time validation target: the full right-looking block Cholesky
# --------------------------------------------------------------------------


def block_cholesky(a_blocks):
    """Right-looking blocked Cholesky over an NB×NB grid of b×b blocks.

    ``a_blocks`` is an (NB, NB, b, b) array of the lower-triangular blocks of
    an SPD matrix.  Returns the (NB, NB, b, b) array of L blocks.  Mirrors
    exactly the task DAG the Rust coordinator generates (cholesky/dag.rs):

        for j in 0..NB:
            L[j,j]  = potrf(A[j,j])
            L[i,j]  = trsm(L[j,j], A[i,j])            i in j+1..NB
            A[i,i] -= syrk(A[i,i], L[i,j])            i in j+1..NB
            A[i,k] -= gemm(A[i,k], L[i,j], L[k,j])    j < k < i
    """
    nb = a_blocks.shape[0]
    blocks = [[a_blocks[i, j] for j in range(nb)] for i in range(nb)]
    for j in range(nb):
        blocks[j][j] = potrf_task(blocks[j][j])
        for i in range(j + 1, nb):
            blocks[i][j] = trsm_task(blocks[j][j], blocks[i][j])
        for i in range(j + 1, nb):
            blocks[i][i] = syrk_task(blocks[i][i], blocks[i][j])
            for k in range(j + 1, i):
                blocks[i][k] = gemm_task(blocks[i][k], blocks[i][j], blocks[k][j])
    return jnp.stack([jnp.stack(row) for row in blocks])


def assemble(blocks):
    """(NB, NB, b, b) block array → (NB·b, NB·b) dense matrix."""
    nb, _, b, _ = blocks.shape
    return blocks.transpose(0, 2, 1, 3).reshape(nb * b, nb * b)


def split(a, nb: int):
    """(NB·b, NB·b) dense matrix → (NB, NB, b, b) block array."""
    n = a.shape[0]
    b = n // nb
    return a.reshape(nb, b, nb, b).transpose(0, 2, 1, 3)


def random_spd(n: int, seed: int = 0, dtype=jnp.float32):
    """Well-conditioned random SPD test matrix (M·Mᵀ + n·I)."""
    m = jax.random.normal(jax.random.PRNGKey(seed), (n, n), dtype=dtype)
    return m @ m.T + n * jnp.eye(n, dtype=dtype)
