//! Fig 1 standalone: why 5 tries per round is enough.
//!
//! Prints the exact hypergeometric success probability of the randomized
//! partner search (eq. 1), its Monte-Carlo validation over the actual
//! implementation draw, and the P → ∞ asymptote the paper quotes.
//!
//! Run: `cargo run --release --example pairing_probability`

use ductr::experiments::fig1;
use ductr::prob::hypergeom::Hypergeometric;

fn main() {
    let fig = fig1::run(10, 10_000, 7);
    println!("{}", fig.render_panel(10));
    println!("{}", fig.render_panel(100));

    println!("tries needed for ≥ 95% success at K = P/2 (the hardest mix):");
    for &p in &[10u64, 100, 1000, 100_000] {
        let n_needed = (1..=20)
            .find(|&n| Hypergeometric::new(p, p / 2, n).success_probability() >= 0.95)
            .expect("under 20 tries");
        println!("  P = {p:>7}: n = {n_needed}");
    }
    println!(
        "\nasymptote (P→∞, K=P/2): 1 − 2⁻ⁿ; n = 5 gives {:.4} — the paper's\n\
         reason for fixing 5 tries per round.",
        Hypergeometric::asymptotic_success(0.5, 5)
    );
}
