//! Using ductr as a library: define your own dependency-aware task graph
//! with the STF builder, run it in the DES with DLB, inspect the traces.
//!
//! The workload here is a deliberately imbalanced "map-reduce": one process
//! owns a big map fan-out whose results funnel through reduction layers.
//!
//! Run: `cargo run --release --example custom_workload`

use std::sync::Arc;

use ductr::config::{Config, Strategy};
use ductr::core::graph::GraphBuilder;
use ductr::core::ids::ProcessId;
use ductr::core::task::TaskKind;
use ductr::sim::engine::SimEngine;
use ductr::util::plot::{self, Series};

fn main() -> ductr::util::error::Result<()> {
    let p = 6;

    // --- build the graph: 48 map tasks on p0, tree-reduce across ranks ---
    let mut gb = GraphBuilder::new();
    let maps: Vec<_> = (0..48)
        .map(|_| {
            let out = gb.data(ProcessId(0), 128, 128); // all mapped on p0!
            gb.task(TaskKind::Synthetic, vec![], out, 40_000_000, None);
            out
        })
        .collect();
    // reduce pairwise until one remains, spreading outputs round-robin
    let mut layer = maps;
    let mut rank = 0u32;
    while layer.len() > 1 {
        let mut next = Vec::new();
        for pair in layer.chunks(2) {
            let home = ProcessId(rank % p as u32);
            rank += 1;
            let out = gb.data(home, 128, 128);
            gb.task(TaskKind::Synthetic, pair.to_vec(), out, 8_000_000, None);
            next.push(out);
        }
        layer = next;
    }
    let graph = gb.build();
    println!(
        "graph: {} tasks, critical path {:.0} Mflop, total {:.0} Mflop",
        graph.num_tasks(),
        graph.critical_path_flops() as f64 / 1e6,
        graph.total_flops() as f64 / 1e6
    );

    // --- run DLB off vs on --------------------------------------------
    let mut results = Vec::new();
    for dlb in [false, true] {
        let mut cfg = Config::default();
        cfg.processes = p;
        cfg.grid = None;
        cfg.dlb_enabled = dlb;
        cfg.strategy = Strategy::Equalizing;
        cfg.wt = 2;
        cfg.delta = 0.001;
        cfg.validate()?;
        let r = SimEngine::from_config(&cfg, Arc::clone(&graph))
            .run()
            .map_err(ductr::util::error::Error::new)?;
        println!(
            "dlb={dlb:<5}  makespan {:.4}s  utilization {:>5.1}%  {}",
            r.makespan,
            r.utilization * 100.0,
            r.counters.summary_line()
        );
        results.push(r);
    }

    // --- show the workload redistribution ------------------------------
    let on = &results[1];
    let series: Vec<Series> = on
        .traces
        .per_process
        .iter()
        .enumerate()
        .map(|(i, tr)| Series::new(format!("p{i}"), tr.resample(on.traces.makespan, 70)))
        .collect();
    println!("{}", plot::plot("w_i(t) with DLB (equalizing)", &series, 70, 12));

    let speedup = results[0].makespan / results[1].makespan;
    println!("DLB speedup on this workload: {speedup:.2}×");
    Ok(())
}
