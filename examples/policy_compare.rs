//! Three balancers, one workload, one table: random pairing (the paper's
//! protocol), work stealing, and neighborhood diffusion racing on a small
//! 3×3 torus — all in the deterministic simulator.
//!
//! Run: `cargo run --release --example policy_compare`

use ductr::cholesky;
use ductr::config::{Config, Grid, PolicyKind, TopologyKind};

fn cfg_for(policy: Option<PolicyKind>) -> Config {
    let mut cfg = Config::default();
    cfg.processes = 9;
    cfg.grid = Some(Grid::new(3, 3));
    cfg.topology = TopologyKind::Torus;
    cfg.nb = 10;
    cfg.block = 128;
    cfg.wt = 3;
    cfg.delta = 0.002;
    cfg.seed = 11;
    match policy {
        Some(p) => cfg.policy = p,
        None => cfg.dlb_enabled = false,
    }
    cfg.validate().expect("valid config");
    cfg
}

fn main() -> ductr::util::error::Result<()> {
    println!("policy comparison: block Cholesky (10×10 blocks) on a 3×3 torus, P = 9\n");
    println!("{:<12} {:>12} {:>10} {:>10} {:>10}", "policy", "makespan_s", "vs_off", "migrated", "requests");

    let off = cholesky::run_sim(&cfg_for(None))?;
    println!(
        "{:<12} {:>12.6} {:>10} {:>10} {:>10}",
        "off", off.makespan, "—", 0, 0
    );

    for policy in PolicyKind::ALL {
        let r = cholesky::run_sim(&cfg_for(Some(policy)))?;
        let vs = (off.makespan - r.makespan) / off.makespan * 100.0;
        println!(
            "{:<12} {:>12.6} {:>9.1}% {:>10} {:>10}",
            policy.to_string(),
            r.makespan,
            vs,
            r.counters.tasks_exported,
            r.counters.requests_sent
        );
    }

    println!("\nSame seed ⇒ same table, every run: the DES is deterministic.");
    Ok(())
}
