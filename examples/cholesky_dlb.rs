//! The paper's headline experiment at full scale (DES): block Cholesky of
//! N = 20 000 on a 2×5 process grid, DLB off vs on, with the §6
//! calibration protocol and ASCII workload traces (Fig 4 left).
//!
//! Run: `cargo run --release --example cholesky_dlb`

use ductr::experiments::fig4;

fn main() -> ductr::util::error::Result<()> {
    let spec = &fig4::CASES[0]; // N=20000, P=10, 2×5
    println!("running {} (DES, S/R = 40, δ = 10 ms) ...", spec.name);

    let case = fig4::run_case(spec, 1)?;
    println!("{}", case.render(10));

    println!("calibrated W_T       : {}", case.calibrated_wt);
    println!("makespan without DLB : {:.4} s", case.off.makespan);
    println!("makespan with DLB    : {:.4} s", case.on.makespan);
    println!("improvement          : {:+.2}%  (paper: 5–6%)", case.improvement() * 100.0);
    println!("tasks migrated       : {}", case.on.counters.tasks_exported);
    println!("pairing              : {}", case.on.counters.summary_line());
    Ok(())
}
