//! §4 cost-model explorer: when does migrating a task pay?
//!
//! Prints Q = (S/R)(D/F) for every task kind across block sizes, local vs
//! remote completion times, and the W_T guideline the paper derives from Q.
//!
//! Run: `cargo run --release --example cost_model`

use ductr::core::task::TaskKind;
use ductr::dlb::costmodel::CostModel;

fn main() {
    // Rackham-like machine balance (paper §4): S/R = 40.
    let mut model = CostModel::new(8.8e9, 2.2e8);
    model.latency = 2e-6;

    println!("machine: S = {:.1e} flop/s, R = {:.1e} doubles/s, S/R = {:.0}\n",
        model.flops_per_sec, model.doubles_per_sec, model.s_over_r());

    println!("{:<8} {:>7} {:>12} {:>12} {:>10} {:>9}", "kind", "block", "T_local", "T_remote", "Q", "W_T floor");
    for kind in [TaskKind::Gemm, TaskKind::Syrk, TaskKind::Trsm, TaskKind::Potrf, TaskKind::Gemv] {
        for b in [64u64, 256, 1024, 2500] {
            let f = kind.flops_for_block(b);
            let d = (model.q_kind(kind, b) * f as f64 / model.s_over_r()) as u64;
            println!(
                "{:<8} {:>7} {:>11.3}ms {:>11.3}ms {:>10.4} {:>9}",
                kind.to_string(),
                b,
                model.local_time(f) * 1e3,
                model.remote_time(f, d) * 1e3,
                model.q_kind(kind, b),
                model.wt_guideline(kind, b),
            );
        }
        println!();
    }

    println!("paper's worked examples:");
    println!(
        "  gemm, D = 3m² (paper's count): Q = 60/m  → m=1000 gives {:.3}",
        model.q(2 * 1000u64.pow(3), 3 * 1000 * 1000)
    );
    println!(
        "  gemv, D = m²: Q = {:.1}  → \"20 tasks can be executed locally in the\nsame time as one task is migrated\"",
        model.q(2 * 1000 * 1000, 1000 * 1000)
    );
}
