fn main() {
    use ductr::cholesky::{self, ProcessGrid};
    use ductr::config::{Config, Grid};
    use ductr::sim::engine::SimEngine;
    use std::sync::Arc;
    let mut cfg = Config::default();
    cfg.processes = 10;
    cfg.grid = Some(Grid::new(2, 5));
    cfg.nb = 12;
    cfg.block = 1667;
    cfg.dlb_enabled = true;
    cfg.wt = 5;
    cfg.validate().unwrap();
    for _ in 0..50 {
        let dag = cholesky::build(cfg.nb, cfg.block, ProcessGrid::new(cfg.effective_grid()));
        let mut eng = SimEngine::from_config(&cfg, Arc::clone(&dag.graph));
        let r = eng.run().unwrap();
        std::hint::black_box(r.makespan);
    }
}
