//! Quickstart: factor a small SPD matrix on 4 threaded processes with DLB,
//! real PJRT kernels, and numeric verification — the full stack in ~30
//! lines of user code.
//!
//! Requires `make artifacts` first.
//!
//! Run: `cargo run --release --example quickstart`

use ductr::cholesky;
use ductr::config::{Config, Grid, Strategy};

fn main() -> ductr::util::error::Result<()> {
    // 6×6 blocks of 32×32 = a 192×192 SPD matrix, 2×2 process grid.
    let mut cfg = Config::default();
    cfg.processes = 4;
    cfg.grid = Some(Grid::new(2, 2));
    cfg.nb = 6;
    cfg.block = 32;
    cfg.dlb_enabled = true;
    cfg.strategy = Strategy::Basic;
    cfg.wt = 2;
    cfg.delta = 0.002;
    cfg.seed = 42;
    cfg.validate()?;

    println!("ductr quickstart: block Cholesky, N = {}, P = {}", cfg.matrix_n(), cfg.processes);
    let report = cholesky::run_real(&cfg)?;

    println!("tasks executed : {}", report.tasks);
    println!("makespan       : {:.4} s", report.makespan);
    println!("residual       : {:.3e}  (‖L·Lᵀ − A‖ / n‖A‖)", report.residual.expect("real mode"));
    println!("dlb            : {}", report.counters.summary_line());

    assert!(report.residual.expect("real mode") < 1e-4, "verification failed");
    println!("\nOK: distributed factorization verified against the input matrix.");
    Ok(())
}
